"""Resilience demo: fault injection, the degradation ladder, circuit
breakers and deadlines on MappingService.

Walks the README "Resilience" section live:

1. prints the degradation ladder of a full-accelerator config;
2. injects a scorer fault mid-request and shows the service degrading
   one rung down with a bit-identical mapping;
3. hammers a rung until its circuit breaker opens, then shows the
   cooldown probe closing it again;
4. serves a hung stage under a request deadline;
5. replays a cache-eviction storm.

Run:  PYTHONPATH=src python examples/resilience_demo.py
"""

import dataclasses

import numpy as np

from repro import faults
from repro.serve import MappingService, degradation_ladder, get_scenario

BASE = "minighost-xk7_sparse-flat-wh"
SCALE = 2048


def _has_jax():
    from repro.core.orderings import resolve_partition_backend
    return resolve_partition_backend("jax") == "jax"


def _request(seed=0, **overrides):
    scen = get_scenario(BASE, scale=SCALE, seed=seed)
    req = scen.request()
    if overrides:
        cfg = dataclasses.replace(scen.config(), **overrides)
        req = dataclasses.replace(req, config=cfg, _signature=None)
    return req


def main():
    jax = _has_jax()
    device = (dict(score_backend="pallas", partition_backend="jax",
                   rotations=4) if jax
              else dict(rotations=4))

    print("== the degradation ladder ==")
    for name, cfg in degradation_ladder(_request(**device).config):
        print(f"  {name:16s} fused={cfg.fused!r:7s} "
              f"score={cfg.score_backend:7s} "
              f"partition={cfg.partition_backend}")
    if not jax:
        print("(jax unavailable: single-rung ladder, device demos "
              "degenerate to the healthy path)\n")

    print("\n== a scorer fault degrades one rung down ==")
    # staged (non-fused) config: the scorer sites are on the hot path
    staged = (dict(score_backend="pallas", partition_backend="numpy",
                   rotations=4) if jax else dict(rotations=4))
    svc = MappingService()
    healthy = svc.map(_request(seed=1, **staged))
    with faults.injected("score.*", "oom", count=2):
        degraded = svc.map(_request(seed=2, **staged))
    h_rung = healthy.result.stats.get("degraded", "full")
    d_rung = degraded.result.stats.get("degraded", "full")
    print(f"  healthy rung : {h_rung}")
    print(f"  faulted rung : {d_rung}")
    if jax:
        no_fault = MappingService().map(_request(seed=2, **staged))
        same = np.array_equal(degraded.result.task_to_proc,
                              no_fault.result.task_to_proc)
        print(f"  degraded mapping bit-identical to healthy: {same}")

    if jax:
        print("\n== the circuit breaker opens, then recovers ==")
        clk = {"t": 0.0}
        svc = MappingService(breaker_threshold=2, breaker_cooldown_s=30.0,
                             clock=lambda: clk["t"])
        spec = faults.install("score.jax", "error")
        try:
            for seed in (3, 4, 5):
                svc.map(_request(seed=seed, score_backend="jax",
                                 rotations=4))
        finally:
            faults.remove(spec)
        s = svc.stats()
        print(f"  breaker_skips={s['breaker_skips']} "
              f"rung_failures={s['rung_failures']}")
        for key, st in s["breakers"].items():
            print(f"  {st['state']:9s} opens={st['opens']} {key}")
        clk["t"] = 30.0  # cooldown elapses; the fault is gone
        resp = svc.map(_request(seed=6, score_backend="jax", rotations=4))
        print(f"  after cooldown probe: degraded="
              f"{resp.result.stats.get('degraded', None)} breakers="
              f"{[v['state'] for v in svc.stats()['breakers'].values()]}")

        print("\n== a hung stage under a deadline ==")
        svc = MappingService(deadline_s=0.2)
        with faults.injected("serve.compute", "slow", delay=3.0, count=1):
            resp = svc.map(_request(seed=7, score_backend="jax",
                                    rotations=4))
        print(f"  served on rung {resp.result.stats['degraded']!r} "
              f"in {resp.latency_s*1e3:.0f}ms "
              f"(deadline_misses={svc.stats()['deadline_misses']})")

    print("\n== a cache-eviction storm ==")
    svc = MappingService()
    first = svc.map(_request(seed=8))
    with faults.injected("serve.cache", "evict", count=1):
        again = svc.map(_request(seed=8))
    same = np.array_equal(first.result.task_to_proc,
                          again.result.task_to_proc)
    print(f"  repeat request after the storm: status={again.status} "
          f"(storms={svc.results.stats()['storms']}), "
          f"result identical: {same}")
    print(f"  third request: status={svc.map(_request(seed=8)).status}")


if __name__ == "__main__":
    main()
