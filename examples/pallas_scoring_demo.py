"""Scoring a TPU logical-mesh mapping search with the Pallas kernel.

Runs ``meshmap.select_mapping`` — the paper's rotation/scaling search
generalised to jax logical meshes — with each scoring backend and
shows that the fused Pallas kernel (interpret mode on CPU, compiled on
TPU) picks the same winner as the bit-exact numpy oracle while only
returning an 8-wide metric vector per candidate to the host.

    PYTHONPATH=src python examples/pallas_scoring_demo.py
"""

import numpy as np

from repro.core import (Allocation, logical_mesh_graph, tpu_v5e_multipod)
from repro.core.metrics import get_evaluator
from repro.kernels.mapscore import ops as mapscore_ops
from repro.meshmap.device_mesh import select_mapping


def main() -> None:
    machine = tpu_v5e_multipod(npods=2, side=8)
    # a fragmented 128-chip allocation across the two pods
    coords = machine.all_coords()
    rng = np.random.default_rng(7)
    alloc = Allocation(machine, coords[rng.choice(len(coords), 128,
                                                  replace=False)])
    axis_sizes, axis_names = (2, 8, 8), ("pod", "data", "model")
    axis_bytes = [1.0, 8.0, 64.0]
    graph = logical_mesh_graph(axis_sizes, tuple(axis_bytes), axis_names)

    results = {}
    for backend in ("numpy", "jax", "pallas"):
        resolved, _ = get_evaluator(backend)
        best, best_m, base_m = select_mapping(
            graph, alloc, axis_bytes, rotations=8, score_backend=backend)
        results[backend] = best
        print(f"[{backend} -> {resolved}] latency_max "
              f"{best_m['latency_max']:.3f} (default "
              f"{base_m['latency_max']:.3f}), weighted_hops "
              f"{best_m['weighted_hops']:.0f}")

    for backend in ("jax", "pallas"):
        same = np.array_equal(results["numpy"].task_to_proc,
                              results[backend].task_to_proc)
        print(f"{backend} winner identical to numpy oracle: {same}")
        assert same
    stats = mapscore_ops.scorer_cache_stats()
    print(f"pallas compile cache: {stats['misses']} compiles, "
          f"{stats['hits']} hits")


if __name__ == "__main__":
    main()
