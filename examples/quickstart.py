"""Quickstart: the paper's geometric task mapping in 40 lines.

Maps a 2D stencil application onto a sparse allocation of a Cray-like
torus and prints the paper's §3 metrics for the default (rank-order)
mapping vs the geometric (MJ + Flipped-Z) mapping.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        identity_mapping, sfc_allocation, stencil_graph)


def main():
    # A Titan-like Gemini 3D torus; the job gets 4096 cores scattered
    # across 4 fragments of a Hilbert-curve allocator (sparse allocation).
    machine = gemini_xk7(dims=(25, 16, 24), cores_per_node=32)
    alloc = sfc_allocation(machine, 4096, nfragments=4, seed=0)

    # The application: a 64x64 grid of tasks, halo-exchange neighbours.
    app = stencil_graph((64, 64))

    # Default mapping: task i -> core i (MPI rank order).
    base = evaluate(app, alloc, identity_mapping(app, alloc))

    # Geometric mapping (paper Alg. 1): Multi-Jagged partitioning of task
    # and machine coordinates with Flipped-Z part numbering, torus
    # shifting, and bandwidth-scaled node coordinates.
    mapper = Mapper(MapperConfig(sfc="FZ", shift=True,
                                 bandwidth_scale=True))
    ours = evaluate(app, alloc, mapper.map(app, alloc))

    print(f"{'metric':>18s} {'default':>12s} {'geometric':>12s}")
    for key in ("average_hops", "weighted_hops", "data_max",
                "latency_max"):
        print(f"{key:>18s} {base[key]:12.2f} {ours[key]:12.2f}")
    red = 1 - ours["latency_max"] / base["latency_max"]
    print(f"\nbottleneck-link latency reduced by {red:.0%}")


if __name__ == "__main__":
    main()
