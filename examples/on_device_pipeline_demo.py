"""Whole-pipeline-on-device mapping: partition + score as ONE program.

Runs ``meshmap.select_mapping`` with ``partition_backend="jax"`` so the
level-synchronous partitioner sweep (``repro.core.partition_jax``)
executes on device, and — paired with a device scorer — the whole
partition -> part match -> score -> winner select chain fuses into a
single jit-compiled program per candidate stack
(``repro.mapping.fused``): zero host<->device transfers between
stages, only the winning permutation returned to host.  The winner is
bit-identical to the all-numpy pipeline (the lexsort tie order is the
oracle), and the compile-cache counters show the whole sweep is ONE
cache entry that repeat calls hit.

The second half demos the ISSUE-9 cold path: ``sfc="H"`` swaps the
device Hilbert state machine (Skilling's transpose) into the same
fused program, and a node-level ``HierarchySpec`` folds the greedy swap
refinement into it too — coarse sweep + refinement, one compile, the
refine trajectory bit-identical to the host ``refine_swaps``.

    PYTHONPATH=src python examples/on_device_pipeline_demo.py
"""

import time

import numpy as np

from repro.core import Allocation, logical_mesh_graph, tpu_v5e_pod
from repro.core import partition_jax
from repro.mapping import fused as fused_mod
from repro.meshmap.device_mesh import select_mapping


def main() -> None:
    machine = tpu_v5e_pod(side=16)
    # a fragmented 128-chip allocation: the identity enumeration is bad,
    # so the geometric (fused-pipeline) candidates win the search
    coords = machine.all_coords()
    rng = np.random.default_rng(7)
    alloc = Allocation(machine, coords[rng.choice(len(coords), 128,
                                                  replace=False)])
    axis_bytes = [8.0, 64.0]
    graph = logical_mesh_graph((16, 8), tuple(axis_bytes),
                               ("data", "model"))

    results = {}
    for pb, sb in (("numpy", "numpy"), ("jax", "jax"), ("jax", "pallas")):
        t0 = time.perf_counter()
        best, best_m, base_m = select_mapping(
            graph, alloc, axis_bytes, rotations=8,
            partition_backend=pb, score_backend=sb)
        dt = time.perf_counter() - t0
        results[(pb, sb)] = best
        stages = best.stats.get("timings", {})
        stage_str = ", ".join(f"{k}={v * 1e3:.1f}ms"
                              for k, v in sorted(stages.items()))
        print(f"[partition={pb} score={sb}] latency_max "
              f"{best_m['latency_max']:.3f} (default "
              f"{base_m['latency_max']:.3f}), cold {dt * 1e3:.0f}ms  "
              f"[{stage_str}]")

    base = results[("numpy", "numpy")]
    for key in (("jax", "jax"), ("jax", "pallas")):
        same = np.array_equal(base.task_to_proc,
                              results[key].task_to_proc)
        print(f"partition={key[0]} score={key[1]} winner identical to "
              f"numpy oracle: {same}")
        assert same

    # a rotation sweep mapped directly through the pipeline: with a
    # device partitioner AND a device scorer the whole sweep is one
    # fused program — stats carry the attribution
    from repro.mapping import (HierarchySpec, MappingPipeline,
                           PipelineConfig)

    pipe = MappingPipeline(PipelineConfig(
        rotations=8, partition_backend="jax", score_backend="jax"))
    res = pipe.map(graph, alloc)
    ref = MappingPipeline(PipelineConfig(rotations=8)).map(graph, alloc)
    assert np.array_equal(res.task_to_proc, ref.task_to_proc)
    t = res.stats["timings"]
    print(f"direct rotation sweep: fused={res.stats['fused']} "
          f"(score={res.stats['fused_score_backend']}), "
          f"fused_s={t['fused_s'] * 1e3:.1f}ms, winner bit-identical to "
          f"the numpy pipeline: True")

    # ISSUE 9: the device Hilbert curve in the same fused program.  The
    # winner must match the all-host Hilbert pipeline bit for bit.
    hj = MappingPipeline(PipelineConfig(
        sfc="H", rotations=8, partition_backend="jax",
        score_backend="jax")).map(graph, alloc)
    hn = MappingPipeline(PipelineConfig(sfc="H", rotations=8)
                         ).map(graph, alloc)
    assert np.array_equal(hj.task_to_proc, hn.task_to_proc)
    print(f"Hilbert sweep on device: fused={hj.stats['fused']}, winner "
          f"bit-identical to the host Hilbert pipeline: True")

    # ... and the one-program cold path: a node-level HierarchySpec
    # folds the
    # swap refinement into the SAME compiled program (coarse Hilbert
    # sweep + propose/delta-score/apply rounds, early exit), with the
    # refine trajectory bit-identical to the host refine_swaps.
    kw = dict(sfc="H", rotations=8, hierarchy=HierarchySpec.node())
    rj = MappingPipeline(PipelineConfig(
        partition_backend="jax", score_backend="jax", **kw)
    ).map(graph, alloc)
    rn = MappingPipeline(PipelineConfig(**kw)).map(graph, alloc)
    assert np.array_equal(rj.task_to_proc, rn.task_to_proc)
    assert rj.stats["refine_history"] == rn.stats["refine_history"]
    print(f"fused refinement: fused_refine={rj.stats['fused_refine']}, "
          f"rounds={rj.stats['refine_rounds_run']}, "
          f"swaps accepted={rj.stats['refine_accepted']}, score "
          f"{rj.stats['refine_initial']:.1f} -> "
          f"{rj.stats['refine_final']:.1f}, trajectory identical to "
          f"host refine_swaps: True")

    pstats = partition_jax.partition_cache_stats()
    fstats = fused_mod.fused_cache_stats()
    print(f"partition compile cache: {pstats['misses']} compiles, "
          f"{pstats['hits']} hits; fused whole-pipeline programs: "
          f"{fstats['misses']} compiles, {fstats['hits']} hits "
          f"(one program per candidate stack)")


if __name__ == "__main__":
    main()
